"""Substrate tests: optimizers, checkpoint/restart, fault tolerance,
straggler watchdog, gradient compression, data pipeline."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import ImageDatasetCfg, MarkovTokens, SyntheticImages, \
    host_slice
from repro.training import checkpoint, ft
from repro.training import optimizer as opt_lib
from repro.training.train import cross_entropy, quantize_grads_int8


# ------------------------------------------------------------- optimizers


@pytest.mark.parametrize("make", [
    lambda: opt_lib.sgd(lr=0.1, momentum=0.9),
    lambda: opt_lib.adamw(lr=0.05),
])
def test_optimizer_minimizes_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = opt_lib.apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_endpoints():
    sched = opt_lib.cosine(1.0, 100)
    assert float(sched(0)) == pytest.approx(1.0)
    assert float(sched(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(sched(50)) == pytest.approx(0.5, abs=1e-6)


def test_grad_clip():
    opt = opt_lib.sgd(lr=1.0, momentum=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.asarray([30.0, 0.0, 40.0])}   # norm 50
    upd, _ = opt.update(g, state, params)
    assert float(jnp.linalg.norm(upd["w"])) == pytest.approx(1.0, rel=1e-5)


def test_cross_entropy_matches_naive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 7, 13)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 13, (4, 7), dtype=np.int64))
    got = float(cross_entropy(logits, labels))
    p = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.mean(jnp.take_along_axis(p, labels[..., None],
                                               -1)))
    assert got == pytest.approx(want, rel=1e-5)


def test_quantize_grads_int8_error_bounded():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    q = quantize_grads_int8(g)
    err = float(jnp.max(jnp.abs(q["w"] - g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert err <= scale * 0.5 + 1e-7


# ------------------------------------------------------------- checkpoint


def _mini_state(v=0.0):
    return {"params": {"a": jnp.full((4, 3), v), "b": [jnp.zeros(2)]},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    st = _mini_state(3.0)
    checkpoint.save(st, d, 7)
    got, step = checkpoint.restore(_mini_state(), d)
    assert step == 7
    np.testing.assert_array_equal(got["params"]["a"], st["params"]["a"])
    assert int(got["step"]) == 3


def test_checkpoint_gc_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        checkpoint.save(_mini_state(float(s)), d, s, keep=2)
    assert checkpoint.latest_step(d) == 5
    assert sorted(os.listdir(d)) == ["step_00000004", "step_00000005"]
    assert checkpoint.validate(d, 5)
    assert not checkpoint.validate(d, 1)


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    d = str(tmp_path / "ck")
    checkpoint.save(_mini_state(1.0), d, 1)
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


# ------------------------------------------------------------- fault tol.


def test_supervisor_restarts_from_checkpoint(tmp_path):
    d = str(tmp_path / "ck")
    calls = {"n": 0}

    def init_state():
        return _mini_state(0.0)

    def step_fn(state, step):
        calls["n"] += 1
        return {"params": state["params"],
                "step": state["step"] + 1}

    inj = ft.FailureInjector(fail_at_steps=(7, 13))
    out = ft.run_supervised(init_state, step_fn, n_steps=20, ckpt_dir=d,
                            ckpt_every=5, injector=inj)
    assert out["restarts"] == 2
    assert out["completed_steps"] == 20
    assert int(out["state"]["step"]) == 20
    # restarted from step 5 and 10: some steps re-executed
    assert calls["n"] > 20


def test_supervisor_gives_up_after_max_failures(tmp_path):
    d = str(tmp_path / "ck2")

    def always_fail(state, step):
        raise ft.SimulatedNodeFailure("boom")
    with pytest.raises(ft.SimulatedNodeFailure):
        ft.run_supervised(_mini_state, always_fail, n_steps=5, ckpt_dir=d,
                          ckpt_every=1, max_failures=2)


def test_straggler_watchdog_flags_slow_steps():
    wd = ft.StragglerWatchdog(warmup=2, slow_factor=2.0)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5)          # 5x slower than EWMA
    assert wd.flagged == [10]
    assert not wd.observe(11, 0.11)     # EWMA not poisoned by the straggler


def test_elastic_restore_resharding(tmp_path):
    """Restore places leaves with explicit shardings (different 'mesh')."""
    d = str(tmp_path / "ck3")
    st = _mini_state(2.0)
    checkpoint.save(st, d, 1)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, _mini_state())
    got, step = checkpoint.restore(_mini_state(), d, shardings=shardings)
    assert got["params"]["a"].sharding.is_equivalent_to(sh, 2)


# ------------------------------------------------------------- data


def test_synthetic_images_deterministic_and_learnable():
    ds1 = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                          n_train=128, n_test=64))
    ds2 = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                          n_train=128, n_test=64))
    np.testing.assert_array_equal(ds1.train[0], ds2.train[0])
    b1 = ds1.batches("train", 8)(0)
    b2 = ds1.batches("train", 8)(0)
    np.testing.assert_array_equal(b1["images"], b2["images"])
    # class-conditional: same-class images correlate more than cross-class
    imgs, labels = ds1.train
    c0 = imgs[labels == 0]
    c1 = imgs[labels == 1]
    if len(c0) > 1 and len(c1) > 0:
        within = np.mean([np.corrcoef(c0[0].ravel(), c.ravel())[0, 1]
                          for c in c0[1:3]])
        across = np.corrcoef(c0[0].ravel(), c1[0].ravel())[0, 1]
        assert within > across


def test_markov_tokens_learnable_structure():
    mt = MarkovTokens(vocab=64, seed=0)
    b = mt.batch(4, 32, step=0)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # successors come from the table most of the time
    hits = 0
    for r in range(4):
        for t in range(31):
            if b["tokens"][r, t + 1] in mt.table[b["tokens"][r, t]]:
                hits += 1
    assert hits / (4 * 31) > 0.7


def test_host_slice():
    assert host_slice(16, 0, 4) == slice(0, 4)
    assert host_slice(16, 3, 4) == slice(12, 16)
