"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes / dtypes / activation kinds, plus hypothesis properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# hypothesis is an optional dev dep (pip extra: test) — bare environments
# must still collect/run the deterministic kernel tests, so only the
# property tests below are guarded.
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.masked_act import masked_act_2d, masked_act_2d_batched
from repro.kernels.rwkv6_scan import rwkv6_scan

KINDS = ["relu", "gelu", "silu", "sqrelu"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("shape", [(8, 128), (37, 200), (128, 512), (3, 64)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_masked_act_matches_oracle(kind, shape, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape).astype(dtype))
    m = jnp.asarray((rng.random(shape[1]) > 0.5).astype(np.float32))
    want = ref.masked_act_ref(x, m, kind=kind)
    got = masked_act_2d(x, m, kind=kind, interpret=True,
                        block_rows=16, block_cols=128)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("kind", ["relu", "gelu"])
def test_masked_act_poly_matches_oracle(kind):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(33, 130)).astype(np.float32))
    m = jnp.asarray((rng.random(130) > 0.3).astype(np.float32))
    poly = jnp.asarray(rng.normal(size=(3, 130)).astype(np.float32) * 0.1)
    want = ref.masked_act_ref(x, m, kind=kind, poly=poly)
    got = masked_act_2d(x, m, poly, kind=kind, interpret=True,
                        block_rows=8, block_cols=128)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


if HAS_HYPOTHESIS:
    @given(rows=st.integers(1, 64), cols=st.integers(1, 300),
           frac=st.floats(0, 1), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_masked_act_mask_semantics(rows, cols, frac, seed):
        """mask==1 ⇒ act(x); mask==0 ⇒ x (identity replacement) — exactly."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32))
        m = jnp.asarray((rng.random(cols) < frac).astype(np.float32))
        y = np.asarray(ref.masked_act_ref(x, m, kind="relu"))
        xn = np.asarray(x)
        keep = np.asarray(m) > 0.5
        np.testing.assert_allclose(y[:, keep], np.maximum(xn[:, keep], 0))
        np.testing.assert_allclose(y[:, ~keep], xn[:, ~keep])
else:
    def test_masked_act_mask_semantics():
        pytest.skip("hypothesis not installed (pip extra: test)")


@pytest.mark.parametrize("kind", ["relu", "gelu"])
@pytest.mark.parametrize("n", [1, 3, 8])
def test_masked_act_batched_matches_per_candidate(kind, n):
    """The stacked-candidate kernel == n independent 2D kernel calls."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, 37, 200)).astype(np.float32))
    m = jnp.asarray((rng.random((n, 200)) > 0.5).astype(np.float32))
    got = masked_act_2d_batched(x, m, kind=kind, interpret=True,
                                block_rows=16, block_cols=128)
    for i in range(n):
        want = masked_act_2d(x[i], m[i], kind=kind, interpret=True,
                             block_rows=16, block_cols=128)
        np.testing.assert_allclose(got[i], want, rtol=1e-6, atol=1e-6)


def test_masked_act_batched_poly_shared_across_candidates():
    rng = np.random.default_rng(4)
    n, rows, cols = 4, 16, 130
    x = jnp.asarray(rng.normal(size=(n, rows, cols)).astype(np.float32))
    m = jnp.asarray((rng.random((n, cols)) > 0.4).astype(np.float32))
    poly = jnp.asarray(rng.normal(size=(3, cols)).astype(np.float32) * 0.1)
    got = masked_act_2d_batched(x, m, poly, kind="relu", interpret=True,
                                block_rows=8, block_cols=128)
    for i in range(n):
        want = ref.masked_act_ref(x[i], m[i], kind="relu", poly=poly)
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)


def test_ops_masked_act_batched_dispatch_matches_kernel():
    """CPU ref fallback of ops.masked_act_batched == interpret-mode kernel."""
    from repro.kernels.ops import masked_act_batched
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(3, 4, 10, 64)).astype(np.float32))
    m = jnp.asarray((rng.random((3, 64)) > 0.5).astype(np.float32))
    via_ref = masked_act_batched(x, m, kind="silu")
    via_kernel = masked_act_batched(x, m, kind="silu", force_pallas=True,
                                    interpret=True)
    np.testing.assert_allclose(via_ref, via_kernel, rtol=1e-5, atol=1e-5)


def test_ops_masked_act_sited_batched_matches_per_candidate_sited():
    """Stacked site masks (N, *site) == N independent masked_act_sited
    calls, on both dispatch paths (CNN-style (H, W, C) site)."""
    from repro.kernels.ops import masked_act_sited, masked_act_sited_batched
    rng = np.random.default_rng(6)
    n, B, site = 3, 2, (4, 4, 8)
    x = jnp.asarray(rng.normal(size=(n, B) + site).astype(np.float32))
    m = jnp.asarray((rng.random((n,) + site) > 0.5).astype(np.float32))
    poly = jnp.asarray(rng.normal(size=(3,) + site).astype(np.float32) * 0.1)
    for kw in ({}, {"force_pallas": True, "interpret": True}):
        got = masked_act_sited_batched(x, m, kind="relu", poly=poly, **kw)
        assert got.shape == x.shape
        for i in range(n):
            want = masked_act_sited(x[i], m[i], kind="relu", poly=poly)
            np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)


def test_masked_act_sited_routed_vmaps_to_stacked_kernel():
    """The custom-vmap entry (the BCD engines' TPU route): vmapping the
    candidate axis must produce exactly what N per-candidate sited calls
    produce — for batched x, unbatched x (mask-independent activations),
    and the poly replacement; unbatched calls fall through to the base."""
    from repro.kernels.ops import masked_act_sited, masked_act_sited_routed
    rng = np.random.default_rng(7)
    n, B, site = 4, 2, (4, 4, 8)
    x = jnp.asarray(rng.normal(size=(n, B) + site).astype(np.float32))
    m = jnp.asarray((rng.random((n,) + site) > 0.5).astype(np.float32))
    poly = jnp.asarray(rng.normal(size=(3,) + site).astype(np.float32) * 0.1)

    # both batched
    got = jax.vmap(lambda xi, mi: masked_act_sited_routed(
        xi, mi, kind="relu", interpret=True))(x, m)
    w = jnp.stack([masked_act_sited(x[i], m[i], kind="relu",
                                    force_pallas=True, interpret=True)
                   for i in range(n)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(w),
                               rtol=1e-6, atol=1e-6)

    # mask-only batched (x shared across candidates — the first mask site)
    x1 = x[0]
    got = jax.vmap(lambda mi: masked_act_sited_routed(
        x1, mi, kind="gelu", interpret=True))(m)
    w = jnp.stack([masked_act_sited(x1, m[i], kind="gelu",
                                    force_pallas=True, interpret=True)
                   for i in range(n)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(w),
                               rtol=1e-5, atol=1e-5)

    # poly replacement, shared across candidates; under jit like the engine
    got = jax.jit(jax.vmap(lambda xi, mi: masked_act_sited_routed(
        xi, mi, kind="relu", poly=poly, interpret=True)))(x, m)
    w = jnp.stack([masked_act_sited(x[i], m[i], kind="relu", poly=poly,
                                    force_pallas=True, interpret=True)
                   for i in range(n)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(w),
                               rtol=1e-5, atol=1e-5)

    # no vmap: falls through to the per-candidate kernel
    got = masked_act_sited_routed(x[0], m[0], kind="relu", interpret=True)
    w = masked_act_sited(x[0], m[0], kind="relu", force_pallas=True,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(w),
                               rtol=1e-6, atol=1e-6)


def test_suffix_route_unbatched_cache_batched_masks():
    """The prefix-reuse engine's layout: a vmapped suffix forward maps
    masks over the candidate axis while the cached prefix activation rides
    in_axes=None (shared across candidates) and feeds further ops.  The
    custom-vmap rule must broadcast x across the candidate axis into the
    stacked kernel and agree with the per-candidate reference."""
    from repro.kernels.ops import masked_act_sited, masked_act_sited_routed
    rng = np.random.default_rng(11)
    n, B, site_shape = 3, 2, (4, 4, 8)
    cached = jnp.asarray(rng.normal(size=(B,) + site_shape)
                         .astype(np.float32))
    masks = jnp.asarray((rng.random((n,) + site_shape) > 0.5)
                        .astype(np.float32))

    def suffix_fn(m, x):
        y = masked_act_sited_routed(x, m, kind="relu", interpret=True)
        return y.reshape(B, -1).sum(-1)          # downstream suffix ops

    got = jax.jit(jax.vmap(suffix_fn, in_axes=(0, None)))(masks, cached)
    want = jnp.stack([
        masked_act_sited(cached, masks[i], kind="relu", force_pallas=True,
                         interpret=True).reshape(B, -1).sum(-1)
        for i in range(n)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_stacked_kernel_route_hint_is_scoped():
    """linearize.stacked_kernel_route flips the trace-time flag and always
    restores it (exceptions included)."""
    from repro.core import linearize
    assert not linearize.stacked_route_active()
    with linearize.stacked_kernel_route():
        assert linearize.stacked_route_active()
        with linearize.stacked_kernel_route(False):
            assert not linearize.stacked_route_active()
        assert linearize.stacked_route_active()
    assert not linearize.stacked_route_active()
    with pytest.raises(RuntimeError):
        with linearize.stacked_kernel_route():
            raise RuntimeError("boom")
    assert not linearize.stacked_route_active()


def test_full_mask_is_pure_activation_and_zero_mask_is_identity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    ones = jnp.ones((128,))
    zeros = jnp.zeros((128,))
    got = masked_act_2d(x, ones, kind="silu", interpret=True)
    np.testing.assert_allclose(got, jax.nn.silu(x), rtol=1e-6, atol=1e-6)
    got = masked_act_2d(x, zeros, kind="silu", interpret=True)
    np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("T,K,V,chunk", [(32, 8, 8, 8), (64, 16, 32, 16),
                                         (64, 8, 16, 32)])
def test_rwkv6_pallas_vs_scan(T, K, V, chunk):
    rng = np.random.default_rng(3)
    BH = 4
    r = jnp.asarray(rng.normal(size=(BH, T, K)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.normal(size=(BH, T, K)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.normal(size=(BH, T, V)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.7, 0.999, size=(BH, T, K)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(BH, K)).astype(np.float32)) * 0.3
    s0 = jnp.asarray(rng.normal(size=(BH, K, V)).astype(np.float32)) * 0.1
    y_ref, s_ref = ops._rwkv6_scan_jnp(r, k, v, w, u, s0)
    y_pl, s_pl = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(y_pl, y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s_pl, s_ref, rtol=3e-4, atol=3e-4)


def test_rwkv6_scan_oracle_vs_python_loop():
    rng = np.random.default_rng(4)
    T, K, V = 24, 4, 8
    r, k = (jnp.asarray(rng.normal(size=(1, T, K)).astype(np.float32))
            for _ in range(2))
    v = jnp.asarray(rng.normal(size=(1, T, V)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.8, 1, size=(1, T, K)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(1, K)).astype(np.float32))
    s0 = jnp.zeros((1, K, V))
    y1, s1 = ref.rwkv6_chunk_ref(r[0], k[0], v[0], w[0], u[0], s0[0])
    y2, s2 = ops._rwkv6_scan_jnp(r, k, v, w, u, s0)
    np.testing.assert_allclose(y2[0], y1, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(s2[0], s1, rtol=2e-5, atol=2e-5)


def test_ops_dispatch_cpu_uses_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 8, 16)).astype(np.float32))
    m = jnp.asarray(np.ones(16, np.float32))
    out = ops.masked_act(x, m, kind="gelu")
    np.testing.assert_allclose(out, ref.masked_act_ref(x, m, kind="gelu"))
