"""Multi-budget BCD sweep on the LM model families (Fig. 4 protocol on
recurrent/SSM and MoE stacks): train -> SNL(B_ref) warm start -> budget
schedule with finetuning between stages.

    PYTHONPATH=src python examples/family_bcd_sweep.py \
        --arch rwkv6_3b --sweep 0.6,0.45 --out-dir runs/rwkv6
    PYTHONPATH=src python examples/family_bcd_sweep.py \
        --arch deepseek_moe_16b --sweep 0.6,0.45 --out-dir runs/moe \
        [--engine suffix] [--chunk-size 4] [--moves remove,swap,stage_drop]

Same driver stack as examples/resnet18_bcd_pipeline.py (launch.sweep on
core.runner: restartable, overlappable, multi-host-ready) but on
``models.lm`` at each family's ``reduced()`` config with Markov-token
data.  What's family-specific is all below the shared engine contract:

* recurrent families (rwkv6_3b, zamba2_2p7b's mamba blocks) run their
  repeated block group as one ``lax.scan`` over stacked params — the
  suffix engine cuts INSIDE that scan at per-repeat virtual sites
  (``s0.rwkv@1``): the prefix returns the scan carry (the residual
  stream) at repeat r, the suffix resumes the remaining repeats from
  that carry checkpoint (docs/bcd_engine.md §Scanned-stack cuts);
* MoE families (deepseek_moe_16b) route per-expert masked FFNs with
  deterministic capacity overflow, so stacked candidate evaluation is
  bitwise-identical to sequential and every engine stays exact.

After the sweep, the mid-scan suffix path is exercised explicitly: a
block of candidates local to the DEEPEST per-repeat stack site is driven
through the suffix evaluator (asserting carry-checkpointed sited chunks
actually ran) and timed against the batched engine; the measured
``speedup_suffix_vs_batched`` lands as one line in BENCH_history.jsonl
(same row shape as benchmarks/bench_bcd_eval.py, so
``SuffixCostModel.calibrated`` consumes it on later runs).
"""
import argparse
import datetime
import json
import os
import subprocess
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import bcd, engine, linearize, masks as M, runner
from repro.core.snl import SNLConfig, finetune, run_snl
from repro.data import MarkovTokens
from repro.launch import compile_cache
from repro.launch import coordinator as coord_lib
from repro.launch import sweep as sweep_lib
from repro.models.lm import LM
from repro.training import train as train_lib


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6_3b", choices=ARCH_IDS,
                    help="model family (reduced config): recurrent "
                         "(rwkv6_3b, zamba2_2p7b), MoE (deepseek_moe_16b, "
                         "mixtral_8x22b), or dense")
    ap.add_argument("--engine", default="suffix",
                    choices=["sequential", "batched", "sharded",
                             "pipelined", "suffix"])
    ap.add_argument("--chunk-size", type=int, default=4)
    ap.add_argument("--prefetch", default="2",
                    help="staged-ahead chunks (pipelined/suffix), or 'auto'")
    ap.add_argument("--moves", default="remove",
                    help="comma-separated move kinds (subset of "
                         f"{','.join(M.MOVE_KINDS)})")
    ap.add_argument("--proposal", default="uniform",
                    choices=list(M.PROPOSALS))
    ap.add_argument("--sweep", default="0.6,0.45",
                    help="descending budget fractions of the total "
                         "nonlinearity count")
    ap.add_argument("--ref-frac", type=float, default=0.75,
                    help="SNL warm-start budget fraction (B_ref)")
    ap.add_argument("--out-dir", required=True)
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=4)
    ap.add_argument("--compile-cache", default=None, metavar="DIR")
    ap.add_argument("--bench-history", default=None,
                    help="append the post-sweep mid-scan suffix-vs-batched "
                         "timing here (default: <out-dir>/BENCH_history"
                         ".jsonl; 'none' to skip)")
    args = ap.parse_args()
    args.moves = tuple(k.strip() for k in args.moves.split(","))
    for kind in args.moves:
        if kind not in M.MOVE_KINDS:
            ap.error(f"--moves: unknown kind {kind!r}")
    if args.prefetch != "auto":
        args.prefetch = int(args.prefetch)
    elif args.engine not in ("pipelined", "suffix"):
        ap.error("--prefetch auto requires --engine pipelined or suffix")
    args.sweep = [float(f) for f in args.sweep.split(",")]
    if args.bench_history is None:
        args.bench_history = os.path.join(args.out_dir,
                                          "BENCH_history.jsonl")
    return args


def make_closures(model, mt, args):
    """Shared training/eval closures — deterministic in their inputs, so a
    resumed process rebuilds identical ones.  Batches follow the LM data
    contract: ``tokens`` (B, S) next-token-shifted against ``labels``."""
    batches_np = lambda i: mt.batch(args.batch, args.seq, i)
    batches = lambda i: {k: jnp.asarray(v)
                         for k, v in batches_np(i).items()}

    def sloss(p, a, batch, soft):
        logits, _ = model.forward(p, a, batch["tokens"], soft=soft)
        return train_lib.cross_entropy(logits, batch["labels"]), 0.0

    # held-out scoring batch (a far-future step the train stream never hits)
    test_b = {k: jnp.asarray(v)
              for k, v in mt.batch(args.eval_batch, args.seq, 10**6).items()}
    test_fn = jax.jit(model.make_param_eval_fn(test_b))

    def test_acc(m, p):
        return float(test_fn(M.as_device(m), p))

    return batches, sloss, test_acc


def _time_sited_sweep(ev, masks, indices, chunk):
    """One full drive of ``indices`` through ``ev`` via the real trial-loop
    path (site-major plan for site-aware backends); returns (seconds,
    [sited chunk names])."""
    flat, layout = M._flatten(masks)
    sited_names = []
    if getattr(ev, "site_aware", False):
        ev.begin_step(masks)
        order, chunks = engine.plan_sited_chunks(ev, indices, layout, chunk)
        sited_names = [c[0] for c in chunks if c[0] is not None]
        gen = engine.materialize_sited(flat, layout, indices, order, chunks)
    else:
        gen = M.materialize_chunks(flat, layout, indices, chunk)
    t0 = time.perf_counter()
    for _accs in engine.evaluate_prefetched(ev, gen):
        pass
    return time.perf_counter() - t0, sited_names


def record_midscan_speedup(args, model, masks, params, eval_b):
    """Exercise the carry-checkpointed suffix path at a mid-scan stack site
    and record its measured speedup over the batched engine.

    Candidates are site-local to the DEEPEST per-repeat virtual site
    (``s<pos>.<kind>@r``, r >= 1): the suffix engine's prefix runs the
    scan up to repeat r and checkpoints the carry; each candidate then
    re-runs only repeats r.. and the tail.  Appends one
    bench-history-compatible line (per_site_depth row keyed "midscan") and
    returns the entry, or None when the family has no scanned stack."""
    mid = [s for s in model.site_order()
           if "@" in s and int(s.rsplit("@", 1)[1]) >= 1]
    if not mid:
        print(f"[midscan] {model.cfg.name}: no per-repeat stack sites — "
              "skipping the mid-scan timing")
        return None
    site = mid[-1]
    rt, reps = 16, 3
    chunk = min(args.chunk_size, rt)
    indices = M.sample_removal_indices_within(
        np.random.default_rng(7), masks, 8, rt, [site],
        repeat_sites=model.site_repeats())
    holder = {"params": params}
    suffix_ev, _, _ = sweep_lib.make_bcd_evaluator(
        "suffix", model, eval_b, holder, chunk_size=chunk, rt=rt,
        fused_kernels="share" not in args.moves)
    batched_ev, _, _ = sweep_lib.make_bcd_evaluator(
        "batched", model, eval_b, holder, chunk_size=chunk, rt=rt)

    # warmup (compile + trie-populate), then check the plan really routed
    # the chunk through a carry-checkpointed sited evaluation
    _, sited = _time_sited_sweep(suffix_ev, masks, indices, chunk)
    _time_sited_sweep(batched_ev, masks, indices, chunk)
    ran_midscan = any("@" in s and int(s.rsplit("@", 1)[1]) >= 1
                      for s in sited)
    trie = suffix_ev.trie
    assert ran_midscan and (trie.misses + trie.extensions) > 0, (
        f"mid-scan candidates at {site} fell back to the full forward "
        f"(sited={sited}) — the carry-checkpoint suffix path did not run")

    # paired timing: alternate engines so host drift cancels in the ratio
    ratios, b_cps, s_cps = [], [], []
    for _ in range(reps):
        dt_s, _ = _time_sited_sweep(suffix_ev, masks, indices, chunk)
        dt_b, _ = _time_sited_sweep(batched_ev, masks, indices, chunk)
        ratios.append(dt_b / dt_s)
        s_cps.append(len(indices) / dt_s)
        b_cps.append(len(indices) / dt_b)
    ratio = round(float(np.median(ratios)), 2)
    frac = float(model.site_prefix_fractions()[site])
    print(f"[midscan] {model.cfg.name} {site}: suffix vs batched "
          f"{ratio:.2f}x (prefix_fraction={frac:.2f}, "
          f"trie misses={trie.misses} extensions={trie.extensions})")

    if args.bench_history == "none":
        return None
    try:
        git = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or None
    except Exception:
        git = None
    entry = {
        "utc": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "git": git,
        "config": {"model": model.cfg.name, "chunk_size": chunk,
                   "eval_batch": args.eval_batch,
                   "n_devices": jax.device_count(),
                   "backend": jax.default_backend(),
                   "source": "family_bcd_sweep"},
        "per_site_depth": {"midscan": {
            "site": site,
            "prefix_fraction": round(frac, 4),
            "mode": "suffix",
            "batched_cands_per_s": round(float(np.median(b_cps)), 2),
            "suffix_cands_per_s": round(float(np.median(s_cps)), 2),
            "speedup_suffix_vs_batched": ratio,
        }},
        "speedup_suffix_vs_batched_midscan": ratio,
    }
    os.makedirs(os.path.dirname(args.bench_history) or ".", exist_ok=True)
    with open(args.bench_history, "a") as f:
        json.dump(entry, f, separators=(",", ":"))
        f.write("\n")
    print(f"[midscan] recorded -> {args.bench_history}")
    return entry


def main():
    args = parse_args()
    counter = None
    if args.compile_cache:
        compile_cache.enable(args.compile_cache)
        counter = compile_cache.hit_counter()

    cfg = get_config(args.arch).reduced()
    model = LM(cfg)
    mt = MarkovTokens(cfg.vocab, seed=0)
    batches, sloss, test_acc = make_closures(model, mt, args)
    masks0 = linearize.init_masks(model.mask_sites())
    total = M.count(masks0)
    b_ref = int(total * args.ref_frac)
    budgets = [int(total * f) for f in args.sweep]
    print(f"family={cfg.name} sites={list(model.mask_sites())} "
          f"repeats={model.site_repeats()}")
    print(f"total nonlinearities {total}; B_ref={b_ref}; "
          f"schedule={budgets}")

    sweep_cfg = sweep_lib.SweepConfig(
        budgets=budgets, out_dir=args.out_dir, name=cfg.name,
        overlap=args.overlap, verbose=True)
    coordinator = coord_lib.from_env(
        default_root=os.path.join(args.out_dir, "coord"))
    if runner.stage_init_exists(sweep_lib.init_dir(sweep_cfg)):
        print(f"== reusing persisted warm start under "
              f"{sweep_lib.init_dir(sweep_cfg)} (skipping train + SNL)")
        init = {"kind": "snl", "masks": masks0,
                "params": model.init(jax.random.PRNGKey(0))}
    else:
        print("== train + SNL to B_ref (the sweep's warm start)")
        params = finetune(model.init(jax.random.PRNGKey(0)), masks0, sloss,
                          batches, steps=args.train_steps, lr=3e-3,
                          use_adam=True)
        alphas = {k: jnp.ones(v.shape) for k, v in masks0.items()}
        res_ref = run_snl(params, alphas, sloss, batches,
                          SNLConfig(b_target=b_ref, lam0=5e-4, kappa=1.5,
                                    epochs=4, steps_per_epoch=5, lr=1e-2,
                                    finetune_steps=10), verbose=True)
        init = res_ref.stage_init()

    holder = {"params": init["params"]}
    eval_b = {"tokens": jnp.asarray(
        mt.batch(args.eval_batch, args.seq, 10**6 + 1)["tokens"])}
    evaluator, eval_acc, set_ctx = sweep_lib.make_bcd_evaluator(
        args.engine, model, eval_b, holder, chunk_size=args.chunk_size,
        rt=6, prefetch=args.prefetch,
        fused_kernels="share" not in args.moves)

    def set_params(p):
        holder["params"] = p
        set_ctx(p)

    def ft(m):
        set_params(finetune(holder["params"], m, sloss, batches,
                            steps=8, lr=1e-3, use_adam=True))

    def make_bcd_cfg(budget):
        return bcd.BCDConfig(
            b_target=budget, drc=max(1, (b_ref - budgets[-1]) // 10), rt=6,
            adt=0.3, chunk_size=args.chunk_size,
            moves=args.moves, proposal=args.proposal)

    def stage_ft(p, m):
        return finetune(p, m, sloss, batches, steps=8, lr=1e-3,
                        use_adam=True)

    payload = sweep_lib.run_sweep(
        sweep_cfg, make_bcd_cfg, eval_acc, init=init, finetune=ft,
        evaluator=evaluator if args.engine != "sequential" else None,
        params_io=(lambda: holder["params"], set_params),
        stage_finetune=stage_ft,
        stage_eval=test_acc,
        notes={"arch": args.arch, "engine": args.engine,
               "prefetch": str(args.prefetch), "overlap": args.overlap,
               "moves": list(args.moves), "proposal": args.proposal},
        coordinator=coordinator)

    print(f"\n=== sweep curve ({payload['artifact']}) ===")
    for s in payload["stages"]:
        acc = s.get("test_acc")
        print(f"B={s['budget']:6d}  steps={s['steps']:3d}  "
              f"acc={acc if acc is not None else float('nan'):.2f}%  "
              f"masks={s['mask_fingerprint'][:12]}")

    if coordinator.is_writer:
        record_midscan_speedup(args, model, payload["final_masks"],
                               holder["params"], eval_b)
    if counter is not None:
        print(counter.log_line())
    return payload


if __name__ == "__main__":
    main()
