"""Quickstart: Network Linearization by Block Coordinate Descent in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py

Trains a small masked CNN on synthetic CIFAR, runs the paper's BCD algorithm
(Alg. 2) to halve the ReLU budget, and reports accuracy + the private-
inference latency this saves under the DELPHI cost model.
"""
import jax
import jax.numpy as jnp

from repro.core import bcd, linearize, masks as M, pi_cost
from repro.core.snl import finetune
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


def main():
    # --- model + data -------------------------------------------------
    cfg = CNNConfig("demo", 4, 16, ((8, 1, 1), (16, 1, 2)), stem_channels=8)
    model = CNN(cfg)
    data = SyntheticImages(ImageDatasetCfg(n_classes=4, image_size=16,
                                           n_train=256, n_test=64))
    params = model.init(jax.random.PRNGKey(0))
    opt = opt_lib.sgd(lr=5e-2, momentum=0.9)
    step, loss_fn = train_lib.make_cnn_train_step(model, opt)
    batches_np = data.batches("train", 32)
    batches = lambda i: {k: jnp.asarray(v) for k, v in batches_np(i).items()}

    masks = linearize.init_masks(model.mask_sites())
    total = M.count(masks)
    print(f"model has {total} ReLUs at {len(masks)} sites")

    ostate = opt.init(params)
    mdev = M.as_device(masks)
    for i in range(80):
        params, ostate, loss, acc = step(params, ostate, mdev, batches(i))
    print(f"trained dense model: train-batch acc {float(acc):.1f}%")

    # --- the paper's algorithm ----------------------------------------
    eval_b = {k: jnp.asarray(v) for k, v in data.train_eval_set(128).items()}

    @jax.jit
    def acc_fn(p, m):
        logits = model.forward(p, m, eval_b["images"])
        return jnp.mean((jnp.argmax(logits, -1) == eval_b["labels"])
                        .astype(jnp.float32)) * 100

    holder = {"params": params}
    eval_acc = lambda m: float(acc_fn(holder["params"], M.as_device(m)))

    def ft(m):
        holder["params"] = finetune(
            holder["params"], m,
            lambda p, mm, b, soft: loss_fn(p, mm, b, soft),
            batches, steps=10, lr=1e-2)

    b_target = total // 2
    res = bcd.run_bcd(
        masks,
        bcd.BCDConfig(b_target=b_target, drc=max(1, total // 16), rt=5,
                      adt=0.3),
        eval_acc, finetune=ft, verbose=True)

    print(f"\nBCD done: ||m||_0 = {M.count(res.masks)} (target {b_target}) — "
          f"sparse by design, no thresholding step")
    print(f"accuracy with half the ReLUs: {eval_acc(res.masks):.1f}%")

    l_ref, l_tgt, speedup = pi_cost.saving(total, b_target,
                                           len(model.mask_sites()))
    print(f"PI online latency (DELPHI model): {l_ref:.3f}s -> {l_tgt:.3f}s "
          f"({speedup:.2f}x faster)")


if __name__ == "__main__":
    main()
