"""End-to-end LM training driver: data -> sharded train loop -> checkpoints ->
fault-tolerant supervisor -> BCD linearization of the trained model.

    PYTHONPATH=src python examples/train_lm.py                 # ~2M params
    PYTHONPATH=src python examples/train_lm.py --dim 768 --layers 12 \
        --steps 300                                            # ~100M params

Runs on whatever devices exist (CPU here; the same code path drives the
production mesh via --mesh data,model).  Demonstrates: Markov-token pipeline,
AdamW + cosine, remat, checkpoint/restart with injected failure, straggler
watchdog, and a final BCD pass that removes 50% of FFN nonlinearities.
"""
import argparse
import dataclasses
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import bcd, engine, linearize, masks as M
from repro.data import MarkovTokens
from repro.models.lm import LM
from repro.training import checkpoint, ft
from repro.training import optimizer as opt_lib, train as train_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--inject-failure", type=int, default=25,
                    help="simulate a node failure at this step (-1 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cfg = dataclasses.replace(
        cfg, n_layers=args.layers, d_model=args.dim,
        n_heads=max(4, args.dim // 32), n_kv_heads=max(2, args.dim // 64),
        head_dim=32, d_ff=args.dim * 3, vocab=args.vocab, dtype="float32")
    model = LM(cfg)
    nparams = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"arch={cfg.name} params={nparams/1e6:.1f}M")

    mt = MarkovTokens(cfg.vocab, seed=0)
    opt = opt_lib.adamw(lr=3e-3, grad_clip=1.0,
                        schedule=opt_lib.cosine(3e-3, args.steps))
    train_step = jax.jit(train_lib.make_train_step(
        model, opt, train_lib.TrainStepCfg(remat=True, dp_axes=())),
        donate_argnums=(0,))
    masks = M.as_device(linearize.init_masks(model.mask_sites()))

    losses = []

    def init_state():
        return train_lib.make_state(model, opt, jax.random.PRNGKey(1))

    def step_fn(state, step):
        b = {k: jnp.asarray(v)
             for k, v in mt.batch(args.batch, args.seq, step).items()}
        state, metrics = train_step(state, b, masks)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            print(f"step {step:4d} loss {losses[-1]:.3f}")
        return state

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    injector = ft.FailureInjector(
        fail_at_steps=(args.inject_failure,) if args.inject_failure >= 0
        else ())
    watchdog = ft.StragglerWatchdog()
    out = ft.run_supervised(init_state, step_fn, n_steps=args.steps,
                            ckpt_dir=args.ckpt_dir, ckpt_every=10,
                            injector=injector, watchdog=watchdog)
    print(f"done: restarts={out['restarts']} "
          f"flagged_straggler_steps={out['flagged_steps']}")
    print(f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}")

    # ---- linearize the trained model with BCD ------------------------
    state = out["state"]
    eval_b = {k: jnp.asarray(v)
              for k, v in mt.batch(16, args.seq, 10**6).items()}

    def token_acc_fn(m):
        logits, _ = model.forward(state["params"], m, eval_b["tokens"])
        return jnp.mean((jnp.argmax(logits, -1) == eval_b["labels"])
                        .astype(jnp.float32)) * 100

    token_acc = jax.jit(token_acc_fn)
    masks_h = linearize.init_masks(model.mask_sites())
    total = M.count(masks_h)
    # Candidate trials go through the batched engine: one vmapped jitted
    # call per chunk of candidate mask trees (masks ride the scanned stack
    # as jit inputs — no recompilation across candidates).
    res = bcd.run_bcd(
        masks_h,
        bcd.BCDConfig(b_target=total // 2, drc=max(1, total // 10), rt=4,
                      adt=0.5, finetune_every_step=False, chunk_size=4),
        lambda m: float(token_acc(M.as_device(m))),
        evaluator=engine.BatchedEvaluator(token_acc_fn, pad_to=4),
        verbose=True)
    print(f"BCD: kept {M.count(res.masks)}/{total} FFN nonlinearities; "
          f"token acc {float(token_acc(M.as_device(res.masks))):.1f}%")


if __name__ == "__main__":
    main()
