"""The paper's full pipeline on ResNet18: train -> SNL(B_ref) -> BCD(B_target)
vs SNL(B_target) head-to-head (Fig. 1 / Table 3 protocol, synthetic CIFAR).

    PYTHONPATH=src python examples/resnet18_bcd_pipeline.py \
        [--image-size 16] [--ref-frac 0.6] [--target-frac 0.4] [--full] \
        [--engine batched] [--chunk-size 8] [--prefetch 2|auto] \
        [--moves remove,add_back,swap,stage_drop,share] \
        [--proposal uniform|sensitivity] [--compile-cache DIR]

--full uses the real ResNet18 geometry at 32x32 (slow on CPU); the default
uses a reduced stage plan with the same code path.  --engine selects the BCD
candidate-evaluation backend (core.engine): 'sequential' is the reference,
'batched' vmaps candidate chunks into one jitted call, 'sharded' additionally
lays the candidate axis out across all local devices, 'pipelined'
double-buffers candidate staging — while the device evaluates chunk k, the
host materializes and transfers chunk k+1 (--prefetch chunks stay in flight;
``--prefetch auto`` measures producer vs consumer rates on the first chunks
and picks the depth itself) — and 'suffix' adds prefix reuse: candidate
chunks are grouped by the segment of their earliest mutated mask site, the
shared forward prefix is computed once per site per step, and only the
suffix is vmapped per candidate (docs/bcd_engine.md).  Selection is
bit-identical across engines for a fixed seed.  --moves widens the
coordinate-descent move set beyond the paper's removals (docs/bcd_engine.md
§Move vocabulary) and --proposal sensitivity weights kinds/sites by their
running acceptance rates; per-kind accepted/proposed counters land in the
sweep artifact and print at exit.  --compile-cache DIR turns
on jax's persistent compilation cache so re-runs and resumed sweeps skip
re-jit (hit counts print at exit).

Sweep mode (the paper's accuracy-vs-budget curve, Fig. 4 protocol):

    PYTHONPATH=src python examples/resnet18_bcd_pipeline.py \
        --sweep 0.55,0.4 --out-dir runs/r18 [--engine pipelined]

descends the budget schedule with warm-starting + finetuning between stages,
checkpointing after every accepted block (launch.sweep / core.runner).  The
run is fully restartable: kill it at any point — SIGKILL included — and
rerunning the same command resumes where it stopped, bit-identically; the
persisted SNL warm start under <out-dir>/init is reused, so a resume skips
training entirely.  The curve lands in <out-dir>/SWEEP_<model>.json.

--overlap starts stage i+1's BCD descent as soon as stage i's accepted
masks land, running stage i's reporting tail (per-stage finetune + test
scoring) concurrently on a worker thread — masks and step logs stay
bit-identical to a serial sweep; only wall-clock changes.

Multi-host: launch one process per rank with REPRO_COORD_RANK /
REPRO_COORD_WORLD / REPRO_COORD_DIR (shared path) / REPRO_COORD_SESSION
exported (launch.coordinator.from_env); rank 0 owns every checkpoint and
artifact, other ranks follow its lineage and verify they resumed the same
manifest fingerprint.  Unset, the run is plain single-process.
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.core import bcd, linearize, masks as M, runner
from repro.core.snl import SNLConfig, finetune, run_snl
from repro.data import ImageDatasetCfg, SyntheticImages
from repro.launch import compile_cache
from repro.launch import coordinator as coord_lib
from repro.launch import sweep as sweep_lib
from repro.models.resnet import CNN, CNNConfig
from repro.training import optimizer as opt_lib, train as train_lib


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--ref-frac", type=float, default=0.6)
    ap.add_argument("--target-frac", type=float, default=0.4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="batched",
                    choices=["sequential", "batched", "sharded",
                             "pipelined", "suffix"])
    ap.add_argument("--chunk-size", type=int, default=8)
    ap.add_argument("--moves", default="remove",
                    help="comma-separated move kinds the descent samples "
                         f"from (subset of {','.join(M.MOVE_KINDS)}); "
                         "'remove' alone replays the paper's Alg. 2 "
                         "bit-identically")
    ap.add_argument("--proposal", default="uniform",
                    choices=list(M.PROPOSALS),
                    help="candidate proposal distribution: 'uniform', or "
                         "'sensitivity' to weight kinds/sites by their "
                         "running acceptance rates")
    ap.add_argument("--prefetch", default="2",
                    help="chunks kept staged ahead (pipelined/suffix "
                         "engines), or 'auto' to pick from measured rates "
                         "(pipelined and suffix)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable the jax persistent compilation cache at "
                         "DIR — sweep restarts stop paying re-jit (cache "
                         "hit counts are logged at exit)")
    ap.add_argument("--sweep", default=None,
                    help="comma-separated descending budget fractions "
                         "(e.g. '0.55,0.4'): run the multi-budget sweep "
                         "driver instead of the single head-to-head")
    ap.add_argument("--out-dir", default=None,
                    help="sweep output/checkpoint directory (required with "
                         "--sweep)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap each sweep stage's reporting tail "
                         "(finetune + test scoring) with the next stage's "
                         "BCD descent; masks stay bit-identical to serial")
    args = ap.parse_args()
    if args.overlap and args.sweep is None:
        ap.error("--overlap only applies to --sweep mode")
    args.moves = tuple(k.strip() for k in args.moves.split(","))
    for kind in args.moves:
        if kind not in M.MOVE_KINDS:
            ap.error(f"--moves: unknown kind {kind!r} (expected a subset "
                     f"of {','.join(M.MOVE_KINDS)})")
    if args.prefetch != "auto":
        try:
            args.prefetch = int(args.prefetch)
        except ValueError:
            ap.error(f"--prefetch must be an integer or 'auto', got "
                     f"{args.prefetch!r}")
    elif args.engine not in ("pipelined", "suffix"):
        ap.error("--prefetch auto requires --engine pipelined or suffix")
    if args.sweep is not None:
        if args.out_dir is None:
            ap.error("--sweep requires --out-dir")
        args.sweep = [float(f) for f in args.sweep.split(",")]
    return args


def build_model_data(args):
    if args.full:
        model = CNN(CNNConfig.resnet18(10, 32))
        data = SyntheticImages(ImageDatasetCfg.cifar10())
    else:
        model = CNN(CNNConfig("r18-mini", 4, args.image_size,
                              ((8, 2, 1), (16, 2, 2)), stem_channels=8))
        data = SyntheticImages(ImageDatasetCfg(
            n_classes=4, image_size=args.image_size, n_train=256, n_test=64))
    return model, data


def make_closures(model, data):
    """The shared training/eval closures (all deterministic in their
    inputs, so a resumed process rebuilds identical ones)."""
    opt = opt_lib.sgd(lr=5e-2, momentum=0.9)
    step, _ = train_lib.make_cnn_train_step(model, opt)
    batches_np = data.batches("train", 32)
    batches = lambda i: {k: jnp.asarray(v)
                         for k, v in batches_np(i).items()}

    def sloss(p, a, batch, soft):
        logits = model.forward(p, a, batch["images"], soft=soft)
        return train_lib.cross_entropy(logits, batch["labels"]), 0.0

    test_b = {k: jnp.asarray(v) for k, v in data.eval_set(64).items()}

    def test_acc(p, m):
        logits = model.forward(p, M.as_device(m), test_b["images"])
        return float(jnp.mean((jnp.argmax(logits, -1) == test_b["labels"])
                              .astype(jnp.float32)) * 100)

    return opt, step, batches, sloss, test_acc


def train_base(model, step, opt, batches, masks0):
    params = model.init(jax.random.PRNGKey(0))
    ostate = opt.init(params)
    mdev = M.as_device(masks0)
    for i in range(80):
        params, ostate, _loss, _acc = step(params, ostate, mdev, batches(i))
    return params


def make_bcd_evaluator(args, model, eval_b, holder, chunk_size, rt):
    """The candidate engine (shared family-agnostic builder —
    ``launch.sweep.make_bcd_evaluator``); returns (evaluator, eval_acc,
    set_ctx).  Share-tied coordinates are overridden outside the fused
    conv/matmul kernels (linearize._apply_share_ties), so the gate stays
    un-fused when the move set can produce ties."""
    return sweep_lib.make_bcd_evaluator(
        args.engine, model, eval_b, holder, chunk_size=chunk_size, rt=rt,
        prefetch=args.prefetch, fused_kernels="share" not in args.moves)


def run_sweep_mode(args):
    model, data = build_model_data(args)
    opt, step, batches, sloss, test_acc = make_closures(model, data)
    masks0 = linearize.init_masks(model.mask_sites())
    total = M.count(masks0)
    b_ref = int(total * args.ref_frac)
    budgets = [int(total * f) for f in args.sweep]
    print(f"total ReLUs {total}; B_ref={b_ref}; schedule={budgets}")

    sweep_cfg = sweep_lib.SweepConfig(
        budgets=budgets, out_dir=args.out_dir, name=model.cfg.name,
        overlap=args.overlap, verbose=True)
    coordinator = coord_lib.from_env(
        default_root=os.path.join(args.out_dir, "coord"))
    if runner.stage_init_exists(sweep_lib.init_dir(sweep_cfg)):
        # resume: params/masks come from the persisted warm start — the
        # untrained init only provides restore templates
        print(f"== reusing persisted warm start under "
              f"{sweep_lib.init_dir(sweep_cfg)} (skipping train + SNL)")
        init = {"kind": "snl", "masks": masks0,
                "params": model.init(jax.random.PRNGKey(0))}
    else:
        print("== train + SNL to B_ref (the sweep's warm start)")
        params = train_base(model, step, opt, batches, masks0)
        alphas = {k: jnp.ones(v.shape) for k, v in masks0.items()}
        res_ref = run_snl(params, alphas, sloss, batches,
                          SNLConfig(b_target=b_ref, lam0=5e-4, kappa=1.5,
                                    epochs=6, steps_per_epoch=5, lr=3e-2,
                                    finetune_steps=15), verbose=True)
        init = res_ref.stage_init()

    holder = {"params": init["params"]}
    eval_b = data.train_eval_set(128)
    evaluator, eval_acc, set_ctx = make_bcd_evaluator(
        args, model, eval_b, holder, args.chunk_size, rt=6)

    def set_params(p):
        holder["params"] = p
        set_ctx(p)

    def ft(m):
        set_params(finetune(holder["params"], m, sloss, batches,
                            steps=12, lr=1e-2))

    def make_bcd_cfg(budget):
        return bcd.BCDConfig(
            b_target=budget, drc=max(1, (b_ref - budgets[-1]) // 10), rt=6,
            adt=0.3, chunk_size=args.chunk_size,
            moves=args.moves, proposal=args.proposal)

    # the reporting tail: pure in (params, masks), so with --overlap it can
    # score stage i on a worker thread while stage i+1's descent mutates the
    # live holder.  The finetuned params are reporting-only — the descent
    # lineage continues from the descent-end state in both modes.
    def stage_ft(p, m):
        return finetune(p, m, sloss, batches, steps=12, lr=1e-2)

    payload = sweep_lib.run_sweep(
        sweep_cfg, make_bcd_cfg, eval_acc, init=init, finetune=ft,
        evaluator=evaluator if args.engine != "sequential" else None,
        params_io=(lambda: holder["params"], set_params),
        stage_finetune=stage_ft,
        stage_eval=lambda m, p: test_acc(p, m),
        notes={"engine": args.engine, "prefetch": str(args.prefetch),
               "overlap": args.overlap, "moves": list(args.moves),
               "proposal": args.proposal},
        coordinator=coordinator)

    report = getattr(evaluator, "auto_report", None)
    if report is not None and coordinator.is_writer:
        print(f"[auto-prefetch] depth={report['prefetch']} "
              f"producer={report['producer_s']:.4f}s "
              f"consumer={report['consumer_s']:.4f}s")
        sweep_lib.update_notes(sweep_cfg, {"auto_prefetch": report})

    print(f"\n=== sweep curve ({payload['artifact']}) ===")
    for s in payload["stages"]:
        acc = s.get("test_acc")
        print(f"B={s['budget']:6d}  steps={s['steps']:3d}  "
              f"acc={acc if acc is not None else float('nan'):.2f}%  "
              f"masks={s['mask_fingerprint'][:12]}")
        kinds = s.get("move_stats", {}).get("kinds", {})
        if kinds:
            rates = "  ".join(
                f"{k}={v['accepted']}/{v['proposed']}"
                for k, v in sorted(kinds.items()))
            print(f"         accepted/proposed: {rates}")
    return payload


def run_head_to_head(args):
    model, data = build_model_data(args)
    opt, step, batches, sloss, test_acc = make_closures(model, data)
    masks0 = linearize.init_masks(model.mask_sites())
    total = M.count(masks0)
    b_ref = int(total * args.ref_frac)
    b_target = int(total * args.target_frac)
    print(f"total ReLUs {total}; B_ref={b_ref}; B_target={b_target}")

    params = train_base(model, step, opt, batches, masks0)

    alphas = {k: jnp.ones(v.shape) for k, v in masks0.items()}
    print("== SNL to B_ref (the paper's starting checkpoint)")
    res_ref = run_snl(params, alphas, sloss, batches,
                      SNLConfig(b_target=b_ref, lam0=5e-4, kappa=1.5,
                                epochs=6, steps_per_epoch=5, lr=3e-2,
                                finetune_steps=15), verbose=True)
    print("== SNL straight to B_target (baseline)")
    res_snl = run_snl(params, alphas, sloss, batches,
                      SNLConfig(b_target=b_target, lam0=5e-4, kappa=1.5,
                                epochs=6, steps_per_epoch=5, lr=3e-2,
                                finetune_steps=15))
    acc_snl = test_acc(res_snl.params, res_snl.masks)

    print(f"== BCD from B_ref to B_target (ours, engine={args.engine})")
    eval_b = data.train_eval_set(128)
    holder = {"params": res_ref.params}
    bcd_cfg = bcd.BCDConfig(
        b_target=b_target, drc=max(1, (b_ref - b_target) // 5), rt=6,
        adt=0.3, chunk_size=args.chunk_size,
        moves=args.moves, proposal=args.proposal)
    evaluator, eval_acc, set_ctx = make_bcd_evaluator(
        args, model, eval_b, holder, bcd_cfg.chunk_size, bcd_cfg.rt)

    def ft(m):
        holder["params"] = finetune(holder["params"], m, sloss, batches,
                                    steps=12, lr=1e-2)
        set_ctx(holder["params"])

    res_bcd = bcd.run_bcd(res_ref.masks, bcd_cfg, eval_acc, finetune=ft,
                          evaluator=evaluator, verbose=True)
    acc_bcd = test_acc(holder["params"], res_bcd.masks)

    print(f"\n=== results at B_target={b_target} ===")
    print(f"SNL : test acc {acc_snl:.2f}%")
    print(f"BCD : test acc {acc_bcd:.2f}%  (budget exact: "
          f"{M.relu_cost(res_bcd.masks) == b_target})")
    kinds = res_bcd.move_stats.get("kinds", {})
    if len(args.moves) > 1 and kinds:
        print("BCD accepted/proposed by kind: " + "  ".join(
            f"{k}={v['accepted']}/{v['proposed']}"
            for k, v in sorted(kinds.items())))


def main():
    args = parse_args()
    counter = None
    if args.compile_cache:
        # before any jit: re-runs and resumed sweeps then reuse compiled
        # executables instead of paying re-jit (the cache key covers
        # jax/XLA versions + options, so stale dirs are cold, not wrong)
        compile_cache.enable(args.compile_cache)
        counter = compile_cache.hit_counter()
    if args.sweep is not None:
        run_sweep_mode(args)
    else:
        run_head_to_head(args)
    if counter is not None:
        print(counter.log_line())


if __name__ == "__main__":
    main()
