"""Batched serving demo: prefill a batch of prompts, decode greedily with a
KV cache, with linearized (masked) FFN activations.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6_3b]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import linearize, masks as M
from repro.models.lm import LM
from repro.training import serve as serve_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_1p6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mask-frac", type=float, default=0.5,
                    help="fraction of nonlinearities to keep")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # linearize half the activation channels (random budget for the demo)
    masks0 = linearize.init_masks(model.mask_sites())
    total = M.count(masks0)
    rng = np.random.default_rng(0)
    masks = M.threshold({k: rng.random(v.shape).astype(np.float32)
                         for k, v in masks0.items()},
                        int(total * args.mask_frac))
    print(f"serving with {M.count(masks)}/{total} nonlinearities kept")
    mdev = M.as_device(masks)

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P), dtype=np.int32))

    prefill = jax.jit(serve_lib.make_prefill(model))
    decode = jax.jit(serve_lib.make_decode_step(model))

    cache = model.init_cache(B, max_len)
    last_logits, cache = prefill(params, mdev, prompts, cache)
    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)

    out = [tok]
    for t in range(G - 1):
        tok, cache = decode(params, mdev, tok, cache,
                            jnp.asarray(P + t, jnp.int32))
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("prompts :", np.asarray(prompts)[:, :8], "...")
    print("generated:", np.asarray(gen))
    print(f"throughput shape: batch={B}, prefill={P} tok, decode={G} steps "
          f"(greedy, KV cache len {max_len})")


if __name__ == "__main__":
    main()
